"""Batched serving runtime: prefill + decode with KV caches.

A minimal production-shaped server: a request queue, fixed-size batch
slots, chunked prefill into per-slot caches and lockstep batched decode
(the decode step is the same function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells).

Kernel backend selection goes through :mod:`repro.api.backends`: a server
constructed with ``backend="interpret"`` (CPU correctness runs) or
``backend="pallas"`` (TPU) traces its jitted step functions under that
backend, so any Segment-plan layers in the model (block-sparse FFN) bake
the right execution mode in — no module-global ``INTERPRET`` flag.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.backends import resolve_backend, use_backend


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (T,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[np.ndarray] = None


class Server:
    """Greedy batched generation over a fixed slot count."""

    def __init__(self, model, params, *, batch_slots: int = 4,
                 max_len: int = 512, backend: Optional[str] = None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.backend = resolve_backend(backend)
        self._decode = jax.jit(self._decode_step)

    def _decode_step(self, params, cache, tok, pos):
        # traced once; the backend context pins plan execution mode then
        with use_backend(self.backend):
            return self.model.decode_step(params, cache, tok, pos)

    def generate(self, requests: List[Request]) -> List[Request]:
        for group in range(0, len(requests), self.slots):
            self._run_batch(requests[group:group + self.slots])
        return requests

    def _run_batch(self, batch: List[Request]) -> None:
        b = len(batch)
        cache = self.model.init_cache(b, self.max_len)
        t_prompt = max(int(r.prompt.shape[0]) for r in batch)
        prompts = np.zeros((b, t_prompt), np.int32)
        for i, r in enumerate(batch):
            prompts[i, :r.prompt.shape[0]] = r.prompt   # left-aligned
        # prefill: feed the prompt through the decode path token-group-wise
        with use_backend(self.backend):
            logits, cache = self.model.decode_step(
                self.params, cache, jnp.asarray(prompts), jnp.int32(0))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new_tokens for r in batch)
        outs = [np.asarray(tok)]
        pos = t_prompt
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(pos))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            outs.append(np.asarray(tok))
            pos += 1
        gen = np.concatenate(outs, axis=1)
        for i, r in enumerate(batch):
            r.out_tokens = gen[i, :r.max_new_tokens]
