from .train_loop import Trainer, TrainerConfig, make_train_step
from .serve import Request, Server

__all__ = ["Trainer", "TrainerConfig", "make_train_step", "Request", "Server"]
