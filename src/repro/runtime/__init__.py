from .train_loop import Trainer, TrainerConfig, make_train_step
from .serve import Engine, Request, Server

__all__ = ["Trainer", "TrainerConfig", "make_train_step", "Engine",
           "Request", "Server"]
